"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).

Prints each benchmark's table plus ``CSV,name,us_per_call,derived`` lines.
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "table1_zoo",
    "fig4_scenarios",
    "fig5_object_correlation",
    "fig6_pixels",
    "table4_rain",
    "fig9_bus",
    "table6_breakdown",
    "table8_sched",
    "fig13_hardware",
    "fig16_system",
    "multi_tenant",
    "static_fix",
    "roofline",
]


def main() -> int:
    import importlib

    only = sys.argv[1:] or MODULES
    failures = []
    for name in only:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            mod.run()
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nAll benchmarks completed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
