"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).

Prints each benchmark's table plus ``CSV,name,us_per_call,derived`` lines,
and mirrors every CSV record into a machine-readable ``BENCH_results.json``
(per-benchmark ``us_per_call`` + derived metrics, wall time, status) so
the perf trajectory is trackable across commits.  Override the output
path with ``BENCH_RESULTS_PATH``.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

MODULES = [
    "table1_zoo",
    "fig4_scenarios",
    "fig5_object_correlation",
    "fig6_pixels",
    "table4_rain",
    "fig9_bus",
    "table6_breakdown",
    "table8_sched",
    "fig13_hardware",
    "fig16_system",
    "multi_tenant",
    "static_fix",
    "anytime",
    "batched",
    "scenarios",
    "roofline",
]


def main() -> int:
    import importlib

    from .common import drain_results

    only = sys.argv[1:] or MODULES
    failures = []
    report: dict[str, dict] = {}
    for name in only:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            drain_results()                 # import-time noise, if any
            t0 = time.time()
            mod.run()
            status = "ok"
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            status = "failed"
            traceback.print_exc()
        report[name] = {
            "status": status,
            "wall_s": round(time.time() - t0, 3),
            "results": drain_results(),
        }

    path = os.environ.get("BENCH_RESULTS_PATH", "BENCH_results.json")
    with open(path, "w") as f:
        json.dump({"benchmarks": report, "failures": failures}, f, indent=2)
    print(f"\nwrote {path} ({sum(len(v['results']) for v in report.values())} records)")

    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nAll benchmarks completed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
