"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).

Prints each benchmark's table plus ``CSV,name,us_per_call,derived`` lines,
and mirrors every CSV record into a machine-readable ``BENCH_results.json``
(per-benchmark ``us_per_call`` + derived metrics, wall time, status) so
the perf trajectory is trackable across commits.  Override the output
path with ``BENCH_RESULTS_PATH``.  ``--trace-out DIR`` makes the
tracing-aware benchmarks (scenarios, pipelined, obs_overhead) also drop
Chrome trace_event JSON artifacts in ``DIR``.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

MODULES = [
    "table1_zoo",
    "fig4_scenarios",
    "fig5_object_correlation",
    "fig6_pixels",
    "table4_rain",
    "fig9_bus",
    "table6_breakdown",
    "table8_sched",
    "fig13_hardware",
    "fig16_system",
    "multi_tenant",
    "static_fix",
    "anytime",
    "batched",
    "pipelined",
    "scenarios",
    "obs_overhead",
    "roofline",
    "cert_overhead",
    "fleet",
    "chaos",
]


def _parse_argv(argv: list[str]) -> list[str]:
    """Split flags from module names; exports --trace-out as
    ``BENCH_TRACE_OUT`` for tracing-aware benchmarks (common.trace_out_path)."""
    if "--trace-out" in argv:
        i = argv.index("--trace-out")
        if i + 1 >= len(argv):
            raise SystemExit(
                "usage: python -m benchmarks.run [--trace-out DIR] [module ...]")
        os.environ["BENCH_TRACE_OUT"] = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    return argv


def main() -> int:
    import importlib

    from .common import drain_results

    only = _parse_argv(sys.argv[1:]) or MODULES
    failures = []
    report: dict[str, dict] = {}
    for name in only:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            drain_results()                 # import-time noise, if any
            t0 = time.time()
            mod.run()
            status = "ok"
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            status = "failed"
            traceback.print_exc()
        report[name] = {
            "status": status,
            "wall_s": round(time.time() - t0, 3),
            "results": drain_results(),
        }

    path = os.environ.get("BENCH_RESULTS_PATH", "BENCH_results.json")
    # merge-update: BENCH_results.json is tracked as the perf trajectory,
    # so a partial run (e.g. `benchmarks.run multi_tenant`) must refresh
    # only the modules it ran instead of clobbering the rest of the file
    merged: dict[str, dict] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f).get("benchmarks", {})
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(report)
    # the file's failure list must describe the file (merged modules, some
    # possibly from earlier runs), not just this invocation
    file_failures = sorted(k for k, v in merged.items()
                           if v.get("status") == "failed")
    with open(path, "w") as f:
        json.dump({"benchmarks": merged, "failures": file_failures}, f, indent=2)
    print(f"\nwrote {path} ({sum(len(v['results']) for v in report.values())} "
          f"records from this run, {len(merged)} modules total)")

    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nAll benchmarks completed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
