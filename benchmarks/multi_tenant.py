"""Contention-variance curve for the multi-tenant serving runtime.

The paper's §IV insight — latency variance is created by the *interaction*
of concurrent DNN tasks sharing an accelerator — reproduced on the
continuous-batching engine:

1. **Measured curve**: step-latency mean / CV / p99 versus the number of
   co-resident decode streams (one capacity bucket per co-residency
   level, each padded batch really computed).
2. **Simulated cross-check**: the same curve from the discrete-event
   scheduler (``sched.contention_curve``) — queueing-only contention,
   no real compute.
3. **Admission A/B**: a mixed workload of achievable and unachievable
   per-token SLOs at full co-residency, served with and without the
   deadline-aware admission controller.  With admission, unachievable
   tenants are shed at the door and the served population keeps its
   deadlines; without it, every seated tight-SLO job misses.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs import get_config
from repro.models import Model
from repro.runtime import (
    AdmissionController,
    AlwaysAdmit,
    MultiTenantConfig,
    MultiTenantEngine,
    RequestQueue,
    StreamRequest,
    poisson_workload,
)
from repro.sched import contention_curve

from .common import csv_line, latency_row, table

STREAM_COUNTS = (1, 2, 4, 8)
TOKENS = 40
PROMPT = 4


def _build(capacity: int, admission=None):
    cfg = get_config("rwkv6-3b", smoke=True).replace(num_layers=2, vocab_size=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = MultiTenantEngine(
        model, params,
        MultiTenantConfig(capacity=capacity, context=64),
        admission=admission if admission is not None else AlwaysAdmit(),
    )
    eng.compile()
    return cfg, eng


def _run_cohort(eng, cfg, n_streams: int, deadline_s=None, seed: int = 0):
    """Drain ``n_streams`` near-simultaneous arrivals through the engine."""
    queue = RequestQueue()
    for req in poisson_workload(
        n_streams, rate_hz=10_000.0, vocab_size=cfg.vocab_size,
        prompt_len=PROMPT, max_new_tokens=TOKENS, deadline_s=deadline_s,
        seed=seed,
    ):
        queue.push(req)
    eng.drain(queue)
    return eng


def measured_curve() -> tuple[list[dict], float]:
    rows = []
    mean_full = float("nan")
    for n in STREAM_COUNTS:
        cfg, eng = _build(capacity=n)
        _run_cohort(eng, cfg, n)
        # steady state: every stream seated and past ramp
        lats = np.asarray(
            [lat for occ, lat in eng.step_log if occ == n][eng.cfg.warmup_steps:]
        )
        rows.append(latency_row(f"streams={n}", lats, {"traces": eng.trace_count}))
        csv_line(f"multi_tenant_step_n{n}", float(np.mean(lats)) * 1e6)
        if n == STREAM_COUNTS[-1]:
            mean_full = float(np.mean(lats))
    return rows, mean_full


def admission_ab(mean_full_s: float) -> list[dict]:
    """Mixed achievable/unachievable SLOs at full co-residency."""
    capacity = STREAM_COUNTS[-1]
    slo_tight = 0.25 * mean_full_s     # nothing at this co-residency meets it
    slo_loose = 8.0 * mean_full_s      # comfortably achievable
    rows = []
    for label, admission in (
        ("no admission", AlwaysAdmit()),
        ("admission", AdmissionController(confidence=0.95)),
    ):
        cfg, eng = _build(capacity, admission=admission)
        # probe stream warms the occupancy→latency model (real deployments
        # seed it from profiling traces, as the paper's schedulers do)
        probe_q = RequestQueue()
        probe_q.push(StreamRequest(
            tenant="probe", prompt=np.arange(1, 1 + PROMPT, dtype=np.int32),
            max_new_tokens=8,
        ))
        eng.drain(probe_q)

        queue = RequestQueue()
        rng = np.random.default_rng(7)
        for i in range(capacity):
            slo = slo_tight if i % 2 == 0 else slo_loose
            queue.push(StreamRequest(
                tenant=f"{'tight' if i % 2 == 0 else 'loose'}-{i:02d}",
                prompt=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32),
                max_new_tokens=TOKENS,
                deadline_s=slo,
            ))
        eng.drain(queue)

        agg = eng.aggregate_report()
        miss_rates = [
            r["miss_rate"] for r in eng.per_tenant_report()
            if r["status"] == "finished" and r["tenant"] != "probe"
        ]
        rows.append({
            "name": label,
            "served": agg["streams"] - 1,     # minus probe
            "shed": agg["shed_streams"],
            "jobs": agg["jobs"],
            "misses": agg["misses"],
            "miss_rate": agg["miss_rate"],
            "p99_tenant_miss": float(np.percentile(miss_rates, 99)) if miss_rates else float("nan"),
        })
    return rows


def run() -> None:
    rows, mean_full = measured_curve()
    table(rows, "measured: step latency vs co-resident streams (rwkv6 smoke)")

    table(
        [
            {"name": f"streams={r['streams']}", "mean_ms": r["mean_s"] * 1e3,
             "cv": r["cv"], "p99_ms": r["p99_s"] * 1e3, "miss_rate": r["miss_rate"]}
            for r in contention_curve(STREAM_COUNTS, seed=0)
        ],
        "simulated cross-check: queueing-only contention (sched.simulate)",
    )

    ab = admission_ab(mean_full)
    table(ab, "admission control A/B at full co-residency (mixed SLOs)")
    base, ctrl = ab[0], ab[1]
    print(
        f"\nadmission control: p99 per-tenant miss rate "
        f"{base['p99_tenant_miss']:.3f} -> {ctrl['p99_tenant_miss']:.3f}, "
        f"aggregate miss rate {base['miss_rate']:.3f} -> {ctrl['miss_rate']:.3f} "
        f"({ctrl['shed']} unachievable-SLO streams shed at the door)"
    )


if __name__ == "__main__":
    run()
