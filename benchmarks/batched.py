"""Batched multi-camera serving: throughput and per-stream tails vs
stream count, batched engine against the serial per-frame loop.

The claim under test (ROADMAP: heavy-traffic scale): N camera streams
served through one shared padded batch — fused device pre-processing,
one vmapped dispatch, one fixed-shape readback, vectorized post — beat
N independent ``run_frame`` passes, and the gap widens with stream
count because the batched tick's fixed costs amortize while the serial
arm pays them N times.  Acceptance: ≥ 2× frames/s at 8 streams for the
headline (top-fidelity) rung; cheaper rungs whose device step is
overhead-bound on a small CPU gain less and are reported honestly.

Also exercises the rung-bucketed anytime scheduler: streams with mixed
deadline budgets split into per-rung buckets, and the shared cost model
learns per-(rung, batch-size) latency.
"""
from __future__ import annotations

import time

import numpy as np

from repro.anytime import build_rungs, calibrate, default_rungs
from repro.batched import BatchedPerceptionEngine, RungBucketScheduler
from repro.perception import SceneConfig, build_pipeline, generate_scene, run_frame

from .common import csv_line, table

N_TICKS = 24
STREAM_COUNTS = (1, 2, 4, 8)
# two_stage is the ladder's top rung (and the paper's post-processing-
# pathological pipeline) — the fidelity a fleet actually wants to serve;
# the others bound the ladder from the cheap end
RUNGS = ("two_stage", "one_stage", "early_exit")
HEADLINE_RUNG = "two_stage"


def _stream_scenes(n_streams: int, n_ticks: int):
    """scenes[tick][stream] — each stream is its own camera (own seed)."""
    return [
        [generate_scene(SceneConfig("city", seed=100 + s), t)
         for s in range(n_streams)]
        for t in range(n_ticks)
    ]


def _paired_arms(built, scenes, n_streams):
    """Per tick, run the serial pass and the batched tick back to back and
    keep the paired walls: adjacent-in-time measurement makes the speedup
    estimate (median of paired ratios) robust to the machine-load drift
    that would otherwise land on one arm only."""
    eng = BatchedPerceptionEngine(built, capacity=n_streams)
    for s in range(n_streams):
        eng.join(f"cam{s}")
    eng.compile()
    serial_walls, batched_walls, serial_lats = [], [], []
    for tick in scenes:
        t0 = time.perf_counter()
        for scene in tick:
            record, _ = run_frame(built, scene)
            serial_lats.append(record.end_to_end)
        serial_walls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng.tick({f"cam{s}": tick[s].image for s in range(n_streams)})
        batched_walls.append(time.perf_counter() - t0)
    assert eng.trace_count == 1, f"batched step retraced: {eng.trace_count}"
    tick_lats = np.asarray([lat for _, lat in eng.tick_log])
    return (np.asarray(serial_walls), np.asarray(batched_walls),
            np.asarray(serial_lats), tick_lats)


def run() -> list[dict]:
    rows = []
    speedup_at_8 = {}
    for rung in RUNGS:
        built = build_pipeline(rung)
        run_frame(built, generate_scene(SceneConfig("city", seed=100), 0))  # warm serial
        for n in STREAM_COUNTS:
            scenes = _stream_scenes(n, N_TICKS)
            sw, bw, serial_lats, tick_lats = _paired_arms(built, scenes, n)
            serial_fps = n / float(np.median(sw))
            batched_fps = n / float(np.median(bw))
            speedup = float(np.median(sw / bw))
            rows.append({
                "rung": rung,
                "streams": n,
                "serial_fps": serial_fps,
                "batched_fps": batched_fps,
                "speedup": speedup,
                "serial_p99_ms": float(np.percentile(serial_lats, 99)) * 1e3,
                "tick_p99_ms": float(np.percentile(tick_lats, 99)) * 1e3,
            })
            csv_line(f"batched/{rung}/streams{n}", 1e6 / batched_fps,
                     f"speedup={speedup:.2f},fps={batched_fps:.0f}")
            if n == max(STREAM_COUNTS):
                speedup_at_8[rung] = speedup
    table(rows, "batched vs serial multi-camera serving (frames/s, p99)")
    for rung, spd in speedup_at_8.items():
        print(f"{rung}: batched is {spd:.2f}x serial frames/s "
              f"at {max(STREAM_COUNTS)} streams")
    csv_line("batched/speedup@8",
             speedup_at_8[HEADLINE_RUNG] * 100,
             ",".join(f"{r}={s:.2f}x" for r, s in speedup_at_8.items()))

    # ---- rung-bucketed anytime scheduling over the batched engines ------
    cal_cfg = SceneConfig("city", seed=4)
    rungs = default_rungs()
    built_rungs = build_rungs(rungs, cal_cfg)
    ladder = calibrate(rungs, cal_cfg, n=8, built=built_rungs)
    top = ladder.top

    sched = RungBucketScheduler(ladder, capacity=8)
    sched.warm()
    # half the cameras run relaxed budgets, half tight: the scheduler
    # should split them into a high-fidelity and a degraded bucket
    for s in range(8):
        budget = 4.0 * top.e2e_mean if s < 4 else 0.9 * ladder.floor.e2e_mean
        sched.add_stream(f"cam{s}", budget)
    bucket_counts: dict[str, int] = {}
    for t in range(16):
        scenes = {f"cam{s}": generate_scene(SceneConfig("city", seed=200 + s), t)
                  for s in range(8)}
        res = sched.tick(scenes)
        for rname, members in res.buckets.items():
            bucket_counts[rname] = bucket_counts.get(rname, 0) + len(members)
    srows = sched.report()
    table(srows, "rung-bucketed scheduler: per-stream outcome (mixed budgets)")
    print("frames served per rung bucket:", dict(sorted(bucket_counts.items())))
    loose = [r for r in srows if int(r["stream"][3:]) < 4]
    tight = [r for r in srows if int(r["stream"][3:]) >= 4]
    csv_line(
        "batched/sched/quality_split",
        float(np.mean([r["mean_quality"] for r in loose])) * 1e3,
        f"loose_q={np.mean([r['mean_quality'] for r in loose]):.3f},"
        f"tight_q={np.mean([r['mean_quality'] for r in tight]):.3f}",
    )
    return rows + srows


if __name__ == "__main__":
    run()
