"""Paper Fig. 5: correlation of post-processing time with detected-object /
proposal counts (0.43 for one-stage YOLOv3 vs 0.91-0.98 for the rest)."""
from repro.perception import SceneConfig, run_lane, run_one_stage, run_two_stage
from .common import csv_line, table

N = 30


def run() -> list[dict]:
    rows = []
    for name, fn in [("one_stage", run_one_stage), ("two_stage", run_two_stage),
                     ("lane", run_lane)]:
        rec = fn(SceneConfig("city", seed=9), n=N)
        corr_obj = rec.correlation_meta("num_objects")
        corr_prop = rec.correlation_meta("num_proposals")
        rows.append({"model": name, "corr_post_vs_objects": corr_obj,
                     "corr_post_vs_proposals": corr_prop})
        csv_line(f"fig5/{name}", 0.0, f"corr={corr_prop:.3f}")
    table(rows, "Fig. 5 analogue — post-processing vs count correlation")
    return rows


if __name__ == "__main__":
    run()
