"""Fleet-sharding scaling benchmark: frames/s vs device count.

Each measurement point runs ``launch/serve.py --fleet`` in a child
process with ``--xla_force_host_platform_device_count=K`` forced into
XLA_FLAGS, so every point executes *real* sharded XLA programs over a
K-device ``Mesh`` — even on a 1-accelerator CI host — and the parent
reads the child's ``--json-out`` report.

The sweep is weak scaling at the ISSUE's operating point (8 streams per
shard): K devices serve 8·K cameras, every rung engine's padded slot
batch carrying a ``NamedSharding`` over the mesh's ``data`` axis.  Tick
cost under the seeded virtual-time model is the max over shards (each
device steps its slice in parallel), so frames/s should grow close to
linearly with K — the affine batch-cost law
(``ModeledStageCost.batch_base + batch_slope·n``) caps the strong-
scaling gain at (0.6 + 0.4·2n)/(0.6 + 0.4·n) < 2, which is why CI
asserts the conservative 1.6× floor at data=2 rather than 2×.

A generous budget (``--slo-ms 200``) pins every stream to the top rung
in all configurations; without it, the 1-device run's contract
controllers degrade rungs under batching pressure and the comparison
stops being apples-to-apples.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from .common import csv_line, table

STREAMS_PER_SHARD = 8
TICKS = 30
DEVICE_COUNTS = (1, 2)
MIN_SCALING_X2 = 1.6


def _run_point(k: int) -> dict:
    """One measurement: 8·K streams on a data=K mesh in a child process
    with K forced host devices."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if not f.startswith("--xla_force_host_platform_device_count"))
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={k}".strip())
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as fh:
        out_path = fh.name
    try:
        cmd = [sys.executable, "-m", "repro.launch.serve", "--fleet",
               "--streams", str(STREAMS_PER_SHARD * k),
               "--mesh", f"data={k}",
               "--ticks", str(TICKS),
               "--slo-ms", "200",
               "--json-out", out_path]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"fleet child (data={k}) failed:\n{proc.stdout}\n{proc.stderr}")
        with open(out_path) as f:
            return json.load(f)
    finally:
        os.unlink(out_path)


def run() -> None:
    rows = []
    reports = {}
    for k in DEVICE_COUNTS:
        doc = _run_point(k)
        reports[k] = doc
        traces = doc["trace_counts"]
        rows.append({
            "devices": k,
            "streams": doc["streams"],
            "frames": doc["frames"],
            "virtual_ms": doc["virtual_s"] * 1e3,
            "frames_per_s": doc["frames_per_vs"],
            "max_traces": max(traces.values()),
            "wall_s": doc["wall_s"],
        })
    table(rows, "fleet scaling (virtual-time frames/s, weak scaling "
               f"at {STREAMS_PER_SHARD} streams/shard)")

    base = reports[1]["frames_per_vs"]
    for k in DEVICE_COUNTS:
        doc = reports[k]
        scaling = doc["frames_per_vs"] / base
        tick_us = doc["virtual_s"] / doc["ticks"] * 1e6
        csv_line(f"fleet_data{k}", tick_us,
                 f"frames_per_s={doc['frames_per_vs']:.1f} "
                 f"scaling_x={scaling:.3f} streams={doc['streams']}")
        if max(doc["trace_counts"].values()) != 1:
            raise AssertionError(
                f"data={k}: a rung engine retraced under fleet serving "
                f"(trace_counts={doc['trace_counts']})")
    scaling2 = reports[2]["frames_per_vs"] / base
    print(f"\nscaling at data=2: {scaling2:.3f}x "
          f"(floor {MIN_SCALING_X2:.1f}x)")
    if scaling2 < MIN_SCALING_X2:
        raise AssertionError(
            f"fleet scaling regression: data=2 delivers {scaling2:.3f}x "
            f"frames/s over data=1, below the {MIN_SCALING_X2:.1f}x floor")


if __name__ == "__main__":
    run()
