"""§Perf hillclimb harness: run roofline analysis for a named variant of an
(arch × shape) pair and print the three terms — the measure step of the
hypothesis → change → measure → validate loop (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.perf_iter yi-6b decode_32k \
        --variant baseline
    PYTHONPATH=src python -m benchmarks.perf_iter internvl2-1b train_4k \
        --variant pure_dp
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.configs import get_config
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import V5E, analyze_extrapolated

# variant name → (cfg_overrides, rules_fn(cfg, mesh) or None, kwargs)
def _pure_dp_rules(cfg, mesh):
    """Small models on a fixed pod mesh: give up TP entirely — batch shards
    over BOTH mesh axes, weights fully replicated."""
    return default_rules(cfg, mesh).with_overrides(
        batch=("data", "model"), heads=None, kv_heads=None,
        mlp=None, vocab=None, expert=None,
    )


VARIANTS = {
    "baseline": ({}, None, {}),
    # triangular chunk schedule: visit only causal/window-allowed KV chunks
    "tri_attn": ({"causal_chunk_skip": True, "attn_chunk_q": 512,
                  "attn_chunk_kv": 1024}, None, {}),
    # pure data parallelism over all 256 chips (small models)
    "pure_dp": ({}, _pure_dp_rules, {}),
    "pure_dp_tri": ({"causal_chunk_skip": True, "attn_chunk_q": 512,
                     "attn_chunk_kv": 1024}, _pure_dp_rules, {}),
    # MoE dispatch-group sweep
    "moe_g256": ({"moe_group_size": 256}, None, {}),
    "moe_g1024": ({"moe_group_size": 1024}, None, {}),
    # gradient accumulation sweep (train shapes)
    "accum2": ({}, None, {"grad_accum": 2}),
    "accum4": ({}, None, {"grad_accum": 4}),
    "accum16": ({}, None, {"grad_accum": 16}),
    # no FSDP (measure the all-gather cost it adds)
    "no_fsdp": ({}, None, {"fsdp": False}),
    # ablation: without the microbatch sharding constraint (GSPMD splits the
    # data axis across the scanned accumulation dim — §Perf finding)
    "no_micro_pin": ({}, None, {"pin_microbatch": False}),
    # attention chunk geometry
    "chunk_1k_2k": ({"attn_chunk_q": 1024, "attn_chunk_kv": 2048}, None, {}),
}


def run_variant(arch: str, shape: str, variant: str, out_path: str | None = None):
    overrides, rules_fn, kwargs = VARIANTS[variant]
    mesh = make_production_mesh()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    rules = rules_fn(cfg, mesh) if rules_fn else None
    rep = analyze_extrapolated(
        arch, shape, mesh, V5E, cfg_overrides=overrides or None,
        rules=rules, **kwargs,
    )
    rec = rep.as_row()
    rec["variant"] = variant
    rec["collectives"] = rep.collectives
    print(f"[{variant}] {rep.bound_summary()}")
    for op, v in sorted(rep.collectives.items(), key=lambda kv: -kv[1]["bytes"]):
        print(f"    {op:20s} count={v['count']:8.1f} bytes={v['bytes']/1e9:8.3f} GB")
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default="perf_iters.jsonl")
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.variant, args.out)


if __name__ == "__main__":
    main()
