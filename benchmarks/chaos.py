"""Chaos benchmark: fault-recovery gates for the sharded fleet.

Two measurements, mirroring the chaos catalog:

* ``shard_loss_rush_hour`` runs in a child process with two forced host
  devices (the ``benchmarks/fleet.py`` pattern), so the shard death
  evacuates streams between *real* mesh shards.  The child is
  ``python -m repro.chaos --check``: every evacuated stream must be
  re-seated within ``RESEAT_BOUND`` ticks of the kill, with zero
  backend compiles (failover is slot churn under a
  ``TraceSentinel(compile_budget=0)``).

* ``sensor_stall_storm`` replays in-process: stalls, corrupt frames, a
  latency spike and transient step faults must produce watchdog fires,
  bounded retries and hysteretic recoveries — with every
  degraded-to-healthy recovery inside ``RECOVERY_BOUND`` ticks.

Both episode reports (ledger, recovery times, trace counts) are dropped
as JSON artifacts in ``chaos_reports/`` for CI upload.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from .common import csv_line, table

RESEAT_BOUND = 3
RECOVERY_BOUND = 20
REPORT_DIR = "chaos_reports"


def _save_report(name: str, doc: dict) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"{name}.report.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True, allow_nan=False)
    return path


def _run_shard_loss() -> dict:
    """Kill-a-shard episode on a forced 2-device host, gated by the
    ``repro.chaos --check`` acceptance criteria in the child itself."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if not f.startswith("--xla_force_host_platform_device_count"))
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count=2".strip())
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as fh:
        out_path = fh.name
    try:
        cmd = [sys.executable, "-m", "repro.chaos",
               "--episode", "shard_loss_rush_hour",
               "--mesh", "data=2",
               "--check",
               "--reseat-bound", str(RESEAT_BOUND),
               "--json-out", out_path]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"chaos child (shard_loss_rush_hour) failed:\n"
                f"{proc.stdout}\n{proc.stderr}")
        with open(out_path) as f:
            return json.load(f)
    finally:
        os.unlink(out_path)


def _run_storm() -> dict:
    """Sensor-fault storm in-process, under a zero-compile sentinel."""
    from repro.analysis.sentinel import TraceSentinel
    from repro.chaos import run_chaos_episode

    sentinel = TraceSentinel(compile_budget=0)
    report, replayer, plan = run_chaos_episode("sensor_stall_storm",
                                               sentinel=sentinel)
    sched = replayer.scheduler
    ledger = report.chaos or {}
    counts = ledger.get("counts", {})
    recovery = ledger.get("recovery_ticks", [])
    problems = []
    if not counts.get("watchdog"):
        problems.append("latency spike never tripped the watchdog")
    if not counts.get("retry"):
        problems.append("armed step faults produced no retry events")
    if not recovery:
        problems.append("no degraded stream ever recovered to healthy")
    elif max(recovery) > RECOVERY_BOUND:
        problems.append(f"slowest recovery took {max(recovery)} ticks "
                        f"(bound {RECOVERY_BOUND})")
    traces = {name: eng.trace_count for name, eng in sched.engines.items()}
    if any(n > 1 for n in traces.values()):
        problems.append(f"a rung engine retraced under chaos ({traces})")
    if problems:
        raise AssertionError("sensor_stall_storm gates failed: "
                             + "; ".join(problems))
    return {
        "episode": "sensor_stall_storm",
        "n_ticks": report.n_ticks,
        "virtual_s": report.clock_s,
        "n_faults": len(plan.events),
        "trace_counts": traces,
        "ledger_counts": counts,
        "recovery_ticks": recovery,
        "report": report.to_dict(),
    }


def run() -> None:
    shard = _run_shard_loss()
    storm = _run_storm()
    rows = [
        {
            "episode": shard["episode"],
            "faults": shard["n_faults"],
            "failovers": shard["ledger_counts"].get("failover", 0),
            "reseat_ticks": shard["reseat_ticks"],
            "recoveries": len(shard["recovery_ticks"]),
            "max_traces": max(shard["trace_counts"].values()),
        },
        {
            "episode": storm["episode"],
            "faults": storm["n_faults"],
            "failovers": storm["ledger_counts"].get("failover", 0),
            "reseat_ticks": None,
            "recoveries": len(storm["recovery_ticks"]),
            "max_traces": max(storm["trace_counts"].values()),
        },
    ]
    table(rows, f"chaos recovery gates (reseat <= {RESEAT_BOUND} ticks, "
                f"recovery <= {RECOVERY_BOUND} ticks, zero retraces)")

    tick_us = shard["report"]["clock_s"] / shard["report"]["n_ticks"] * 1e6
    csv_line("chaos_shard_loss", tick_us,
             f"failovers={shard['ledger_counts'].get('failover', 0)} "
             f"reseat_ticks={shard['reseat_ticks']} "
             f"max_traces={max(shard['trace_counts'].values())}")
    tick_us = storm["virtual_s"] / storm["n_ticks"] * 1e6
    worst = max(storm["recovery_ticks"])
    csv_line("chaos_storm", tick_us,
             f"watchdog={storm['ledger_counts'].get('watchdog', 0)} "
             f"retries={storm['ledger_counts'].get('retry', 0)} "
             f"worst_recovery_ticks={worst}")

    for name, doc in (("shard_loss_rush_hour", shard),
                      ("sensor_stall_storm", storm)):
        path = _save_report(name, doc)
        print(f"wrote {path}")


if __name__ == "__main__":
    run()
