"""Paper §V + Figs. 15-17: the end-to-end perception graph.

Camera → {detector, segmentation(lane), slam-proxy} over the pub/sub broker
(simulated transport delays + REAL pipeline compute), fused by the
approximate-time synchronizer.  Claims: (a) total delay ≫ total inference
for bus-fed modules, (b) running modules concurrently inflates tails vs
isolated runs, (c) a larger synchronizer queue damps fusion-delay variance.
"""
import numpy as np

from repro.bus import Broker, CopyTransport
from repro.core.stats import coefficient_of_variation as cv, summarize, tail_ratio
from repro.perception import ApproxTimeSynchronizer, SceneConfig
from repro.perception.pipelines import run_lane, run_one_stage
from repro.sched import SimConfig, StageSpec, TaskSpec, simulate
from .common import csv_line, table

MB = 1024 * 1024
N_FRAMES = 200
PERIOD = 0.1


def _module_latency_models():
    """Per-module (mean, jitter, proposal-scaled?) from the real pipelines,
    measured once, then replayed through the contention simulator."""
    one = run_one_stage(SceneConfig("city", seed=12), n=16).end_to_end_series()
    lane = run_lane(SceneConfig("city", seed=12), n=16).end_to_end_series()
    return {
        "detector": (float(np.mean(one)), float(np.std(one) / np.mean(one))),
        "segmentation": (float(np.mean(lane)), float(np.std(lane) / np.mean(lane))),
        "slam": (0.012, 0.25),
    }


def run() -> list[dict]:
    mods = _module_latency_models()
    rows = []

    # --- isolated vs concurrent execution (contention over 2 host cores)
    def tasks(concurrent: bool, which: str):
        ts = []
        for name, (mean, jit) in mods.items():
            if not concurrent and name != which:
                continue
            ts.append(TaskSpec(name, PERIOD, (
                StageSpec("pre", "cpu", 0.15 * mean, 0.1),
                StageSpec("infer", "accel", 0.55 * mean, max(jit, 0.05)),
                StageSpec("post", "cpu", 0.30 * mean, max(jit, 0.05)),
            ), n_jobs=N_FRAMES))
        return ts

    iso, conc = {}, {}
    for name in mods:
        r = simulate(tasks(False, name), SimConfig(cpu_cores=2, seed=1))
        iso[name] = r.latencies[name]
    r = simulate(tasks(True, ""), SimConfig(cpu_cores=2, seed=1))
    for name in mods:
        conc[name] = r.latencies[name]

    broker = Broker(transport=CopyTransport(), seed=0)
    # image topic latency (6.2MB to 3 subscribers) adds the paper's I/O term
    img_delay = broker.transport.latencies(
        __import__("repro.bus", fromlist=["Message"]).Message("img", int(6.2 * MB)),
        3, broker.rng,
    )

    for name in mods:
        i, c = iso[name], conc[name]
        rows.append({
            "module": name,
            "iso_mean_ms": i.mean() * 1e3, "iso_cv": cv(i),
            "conc_mean_ms": c.mean() * 1e3, "conc_cv": cv(c),
            "conc_p99_ms": float(np.percentile(c, 99)) * 1e3,
            "tail99_ratio": tail_ratio(c),
        })
        csv_line(f"fig16/{name}", rows[-1]["conc_mean_ms"] * 1e3,
                 f"iso_cv={rows[-1]['iso_cv']:.3f},conc_cv={rows[-1]['conc_cv']:.3f}")
    table(rows, "Fig. 15/16 analogue — isolated vs concurrent modules")

    # --- fusion delay vs queue size (Fig. 17)
    frows = []
    rng = np.random.default_rng(3)
    for q in (100, 1000):
        sync = ApproxTimeSynchronizer(list(mods), queue_size=q, slop=PERIOD)
        for i in range(N_FRAMES):
            stamp = i * PERIOD
            for j, name in enumerate(mods):
                lat = conc[name][i % len(conc[name])] + float(img_delay[j])
                # bursty middleware stalls (the paper's 10s worst case)
                if rng.random() < 0.02:
                    lat += rng.uniform(0.5, 2.0)
                sync.add(name, stamp, None, now=stamp + lat)
        d = np.array(sync.delays())
        frows.append({
            "queue_size": q, "events": len(d),
            "mean_ms": d.mean() * 1e3,
            "p99_ms": float(np.percentile(d, 99)) * 1e3,
            "max_ms": d.max() * 1e3,
            "cv": cv(d),
        })
        csv_line(f"fig17/queue_{q}", frows[-1]["mean_ms"] * 1e3,
                 f"cv={frows[-1]['cv']:.3f}")
    table(frows, "Fig. 17 analogue — fusion delay vs synchronizer queue")
    return rows + frows


if __name__ == "__main__":
    run()
