"""Paper Fig. 9: ROS1-IPC vs ROS2-DDS message latency vs subscriber count,
for 62KB / 6.2MB messages — validates the crossover and the 4-fast/4-slow
worker-pool split."""
import numpy as np

from repro.bus import CopyTransport, DatagramTransport, Message, publish_latencies
from .common import csv_line, table

KB, MB = 1024, 1024 * 1024


def run() -> list[dict]:
    rows = []
    msgs = [Message("msg1_62KB", 62 * KB), Message("msg2_6.2MB", int(6.2 * MB))]
    for msg in msgs:
        for transport in (CopyTransport(), DatagramTransport()):
            for n in (1, 2, 4, 8):
                lat = publish_latencies(transport, msg, n, n_messages=150)
                rows.append({
                    "msg": msg.name, "transport": transport.name, "subs": n,
                    "mean_ms": lat.mean() * 1e3,
                    "range_ms": float(np.ptp(lat)) * 1e3,
                    "p99_ms": float(np.percentile(lat, 99)) * 1e3,
                })
            csv_line(f"fig9/{msg.name}/{transport.name}", rows[-1]["mean_ms"] * 1e3,
                     f"range8={rows[-1]['range_ms']:.2f}ms")
    table(rows, "Fig. 9 analogue — transport latency vs subscribers")
    # the paper's fast/slow split check
    lat8 = publish_latencies(DatagramTransport(), msgs[1], 8, n_messages=100).mean(0)
    print(f"DDS 6.2MB x8 per-subscriber means (ms): {np.sort(lat8) * 1e3}")
    return rows


if __name__ == "__main__":
    run()
