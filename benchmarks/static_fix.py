"""Beyond-paper artifact: the static-shape mitigation quantified.

Same scenes, same backbone: dynamic host post-processing (paper-faithful
pathology) vs static-shape device post-processing (ours) — report the c_v /
range / tail reduction for detection and lane pipelines.
"""
from repro.core.variance import variance_reduction
from repro.perception import SceneConfig, run_lane, run_lane_static, run_one_stage, run_two_stage
from .common import csv_line, table

N = 30


def run() -> list[dict]:
    cfg = SceneConfig("city", seed=10)
    rows = []
    for name, dyn_fn, sta_fn in [
        ("detection", run_two_stage, run_one_stage),
        ("lane", run_lane, run_lane_static),
    ]:
        dyn = dyn_fn(cfg, n=N)
        sta = sta_fn(cfg, n=N)
        rep = variance_reduction(
            dyn.stage_series("post_processing"), sta.stage_series("post_processing")
        )
        rep_e2e = variance_reduction(dyn.end_to_end_series(), sta.end_to_end_series())
        import numpy as np
        dyn_post = dyn.stage_series("post_processing")
        sta_post = sta.stage_series("post_processing")
        rows.append({
            "pipeline": name,
            # σ and range are the variance-elimination evidence; cv of the
            # static path is relative jitter of a ~µs readback (misleading)
            "post_sigma_ms_dyn": float(np.std(dyn_post)) * 1e3,
            "post_sigma_ms_static": float(np.std(sta_post)) * 1e3,
            "post_range_ms_dyn": rep["range_before"] * 1e3,
            "post_range_ms_static": rep["range_after"] * 1e3,
            "e2e_cv_dynamic": rep_e2e["cv_before"],
            "e2e_cv_static": rep_e2e["cv_after"],
        })
        csv_line(f"static_fix/{name}", 0.0,
                 f"post_sigma_ms {rows[-1]['post_sigma_ms_dyn']:.3f}"
                 f"->{rows[-1]['post_sigma_ms_static']:.3f}")
    table(rows, "Static-shape mitigation — variance elimination (ours)")
    return rows


if __name__ == "__main__":
    run()
