"""Pipelined executor: depth sweep vs the synchronous engine.

The claim under test (ISSUE 5 / ROADMAP "as fast as the hardware
allows"): a depth-k software pipeline over the device-resident batch —
frame *t+1*'s read + dirty-slot upload overlapping frame *t*'s fused
device step overlapping frame *t−1*'s host post — serves more frames
per second than the synchronous engine, which pays read, upload,
compute, and post strictly in sequence.

The measured loop is the full *serving* loop the scenario replayer and
any camera harness actually run: per tick, acquire every stream's frame
(the paper's §III read stage — here the synthetic-camera scene
generator), then serve the batch.  Both arms run the identical loop;
only the engine depth differs (depth 1 IS the synchronous PR 3 path).
Blocks of ticks alternate round-robin across depths so machine-load
drift lands on every arm equally; the reported figure per arm is its
best block (hypervisor steal only ever inflates a block).

Honest accounting of what to expect on a small host: the fused step for
the top-fidelity rung saturates a 2-core CPU's memory bandwidth at 8
streams, so overlap has little idle silicon to harvest there — the win
is largest where the device step leaves the host genuinely idle
(2–4 streams, or cheap rungs), and shrinks toward 1× as the step
becomes the only cost.  The depth-2 arm must never be slower than
depth-1 beyond noise (asserted, CI smoke).

Also verified here: per-tick host→device traffic is *dirty slots only* —
a capacity-8 engine serving 3 streams uploads 3 frames, not 8 (the PR 3
engine re-uploaded the full padded batch every tick).
"""
from __future__ import annotations

import time

import numpy as np

from repro.batched import BatchedPerceptionEngine
from repro.perception import SceneConfig, build_pipeline, generate_scene

from .common import csv_line, table, trace_out_path

RUNG = "two_stage"              # the ladder's top rung (paper's dynamic-
                                # shape pipeline) — the headline fidelity
STREAM_COUNTS = (2, 4, 8)
DEPTHS = (1, 2, 3)
TICKS_PER_BLOCK = 10
BLOCK_REPS = 4
SMOKE_TOLERANCE = 0.90          # d2 fps >= 0.9 x d1 fps @8: non-flaky floor


def _serve_block(eng, cfgs, n_ticks, tick0):
    """One timed block of the serving loop: read (scene gen) + serve.
    Returns (mean_wall_per_tick, per_tick_walls) with the pipeline
    drained so no frame and no in-flight work leaks across blocks."""
    n = len(cfgs)
    ticks = []
    t0 = time.perf_counter()
    for t in range(n_ticks):
        ta = time.perf_counter()
        frames = {f"cam{s}": generate_scene(cfgs[s], tick0 + t).image
                  for s in range(n)}
        eng.tick(frames)
        ticks.append(time.perf_counter() - ta)
    eng.flush()                  # retire the tail of the pipe
    wall = (time.perf_counter() - t0) / n_ticks
    return wall, ticks


def run() -> list[dict]:
    rows = []
    fps_at = {n: {} for n in STREAM_COUNTS}
    trace_path = trace_out_path("pipelined")
    obs = None
    if trace_path:
        from repro.obs import Observatory
        obs = Observatory()
    for n in STREAM_COUNTS:
        cfgs = [SceneConfig("city", seed=100 + s) for s in range(n)]
        engines = {}
        for d in DEPTHS:
            built = build_pipeline(RUNG)
            eng = BatchedPerceptionEngine(built, capacity=n, depth=d,
                                          obs=obs,
                                          obs_tag=f"streams{n}/depth{d}")
            for s in range(n):
                eng.join(f"cam{s}")
            eng.compile()
            _serve_block(eng, cfgs, 3, 0)          # warm (loop + caches)
            engines[d] = eng

        walls = {d: [] for d in DEPTHS}
        tick_walls = {d: [] for d in DEPTHS}
        for rep in range(BLOCK_REPS):
            # round-robin so load drift lands on every depth equally
            for d in DEPTHS:
                wall, ticks = _serve_block(engines[d], cfgs, TICKS_PER_BLOCK,
                                           1 + rep * TICKS_PER_BLOCK)
                walls[d].append(wall)
                tick_walls[d].extend(ticks)

        for d in DEPTHS:
            eng = engines[d]
            best = min(walls[d])
            fps = n / best
            fps_at[n][d] = fps
            recs = eng.recorder.records
            host = np.asarray([r.end_to_end for r in recs])
            h2d = np.asarray([r.meta.get("h2d_bytes", 0.0) for r in recs])
            stale = max((r.meta.get("staleness_ticks", 0.0) for r in recs),
                        default=0.0)
            assert eng.trace_count == 1, \
                f"step retraced at depth {d}: {eng.trace_count}"
            rows.append({
                "rung": RUNG,
                "streams": n,
                "depth": d,
                "frames_per_s": fps,
                "tick_wall_ms": best * 1e3,
                "host_ms_per_tick": float(host.mean()) * 1e3,
                "tick_p99_ms": float(np.percentile(
                    np.asarray(tick_walls[d]), 99)) * 1e3,
                "tick_cv": float(np.std(tick_walls[d]) /
                                 np.mean(tick_walls[d])),
                "h2d_kb_per_tick": float(h2d.mean()) / 1024.0,
                "staleness": int(stale),
            })
            csv_line(f"pipelined/{RUNG}/streams{n}/depth{d}",
                     best * 1e6,
                     f"fps={fps:.0f},host_ms={host.mean()*1e3:.2f},"
                     f"h2d_kb={h2d.mean()/1024.0:.0f},stale={int(stale)}")
        for d in (2, 3):
            spd = fps_at[n][d] / fps_at[n][1]
            csv_line(f"pipelined/speedup@{n}/depth{d}", spd * 100,
                     f"{spd:.2f}x_vs_sync")
    table(rows, "pipelined executor: depth sweep vs synchronous engine")
    for n in STREAM_COUNTS:
        print(f"{n} streams: depth2 {fps_at[n][2]/fps_at[n][1]:.2f}x, "
              f"depth3 {fps_at[n][3]/fps_at[n][1]:.2f}x sync frames/s")

    # ---- dirty-slot H2D: partial occupancy uploads only what changed ----
    built = build_pipeline(RUNG)
    eng = BatchedPerceptionEngine(built, capacity=8, depth=2)
    for s in range(3):
        eng.join(f"cam{s}")
    eng.compile()
    cfgs = [SceneConfig("city", seed=100 + s) for s in range(3)]
    for t in range(4):
        eng.tick({f"cam{s}": generate_scene(cfgs[s], t).image
                  for s in range(3)})
    eng.flush()
    frame_bytes = int(np.prod(eng.image_shape)) * 4
    h2d = [r.meta["h2d_bytes"] for r in eng.recorder.records]
    full_batch = 8 * frame_bytes
    assert all(b == 3 * frame_bytes for b in h2d), \
        f"expected dirty-only H2D (3 frames), got {h2d}"
    print(f"capacity-8 engine, 3 live streams: {h2d[0]/1024:.0f} KB/tick "
          f"uploaded (PR 3 full-batch rebuild: {full_batch/1024:.0f} KB)")
    csv_line("pipelined/h2d_dirty_fraction",
             h2d[0] / full_batch * 100,
             f"dirty_kb={h2d[0]/1024:.0f},full_kb={full_batch/1024:.0f}")

    if obs is not None:
        obs.write_trace(trace_path, process_label="pipelined")
        print(f"wrote Chrome trace to {trace_path} "
              f"({obs.tracer.n_recorded} spans, {obs.tracer.dropped} dropped)")

    # ---- CI smoke: the pipeline must never lose to sync beyond noise ----
    d1, d2 = fps_at[max(STREAM_COUNTS)][1], fps_at[max(STREAM_COUNTS)][2]
    assert d2 >= SMOKE_TOLERANCE * d1, (
        f"depth-2 fps {d2:.0f} < {SMOKE_TOLERANCE} x depth-1 fps {d1:.0f} "
        f"at {max(STREAM_COUNTS)} streams")
    return rows


if __name__ == "__main__":
    run()
