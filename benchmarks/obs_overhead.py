"""Observability overhead: the tracer must not perturb what it measures.

The obs layer's contract (ISSUE 7 acceptance): attaching the span tracer
+ metrics hub to the batched serving loop costs less than 3% frames/s at
the headline configuration — 8 streams, depth 2, top-fidelity rung.  Two
identical engines run the identical serving loop; one carries an
``Observatory`` (per-tick span emission into the preallocated ring +
streaming-sketch updates), the other runs bare.  Blocks of ticks
alternate round-robin across the two arms so machine-load drift lands on
both equally, and each arm reports its best block (steal only ever
inflates a block).

Asserted (CI smoke): traced frames/s >= 0.97 x untraced, and zero spans
dropped at the default ring capacity.
"""
from __future__ import annotations

import time

from repro.batched import BatchedPerceptionEngine
from repro.obs import Observatory
from repro.perception import SceneConfig, build_pipeline, generate_scene

from .common import csv_line, table, trace_out_path

RUNG = "two_stage"
N_STREAMS = 8
DEPTH = 2
TICKS_PER_BLOCK = 10
BLOCK_REPS = 6
SMOKE_TOLERANCE = 0.97          # acceptance floor: traced >= 0.97 x bare


def _serve_block(eng, cfgs, n_ticks, tick0):
    """One timed block of the serving loop (read + serve), pipe drained."""
    n = len(cfgs)
    t0 = time.perf_counter()
    for t in range(n_ticks):
        frames = {f"cam{s}": generate_scene(cfgs[s], tick0 + t).image
                  for s in range(n)}
        eng.tick(frames)
    eng.flush()
    return (time.perf_counter() - t0) / n_ticks


def run() -> list[dict]:
    cfgs = [SceneConfig("city", seed=100 + s) for s in range(N_STREAMS)]
    obs = Observatory()
    engines = {}
    for arm, ob in (("off", None), ("on", obs)):
        built = build_pipeline(RUNG)
        eng = BatchedPerceptionEngine(built, capacity=N_STREAMS, depth=DEPTH,
                                      obs=ob, obs_tag=f"bench/{arm}")
        for s in range(N_STREAMS):
            eng.join(f"cam{s}")
        eng.compile()
        _serve_block(eng, cfgs, 3, 0)          # warm (loop + caches)
        engines[arm] = eng

    walls = {arm: [] for arm in engines}
    for rep in range(BLOCK_REPS):
        # round-robin so load drift lands on both arms equally
        for arm, eng in engines.items():
            walls[arm].append(
                _serve_block(eng, cfgs, TICKS_PER_BLOCK,
                             1 + rep * TICKS_PER_BLOCK))

    fps = {arm: N_STREAMS / min(w) for arm, w in walls.items()}
    ratio = fps["on"] / fps["off"]
    ticks_on = engines["on"].ticks
    spans_per_tick = obs.tracer.n_recorded / max(1, ticks_on)

    rows = []
    for arm in ("off", "on"):
        rows.append({
            "arm": f"tracing_{arm}",
            "streams": N_STREAMS,
            "depth": DEPTH,
            "frames_per_s": fps[arm],
            "tick_wall_ms": min(walls[arm]) * 1e3,
            "spans": obs.tracer.n_recorded if arm == "on" else 0,
            "dropped": obs.tracer.dropped if arm == "on" else 0,
        })
        csv_line(f"obs_overhead/{RUNG}/streams{N_STREAMS}/tracing_{arm}",
                 min(walls[arm]) * 1e6, f"fps={fps[arm]:.0f}")
    csv_line("obs_overhead/fps_ratio", ratio * 100,
             f"{ratio:.3f}x_traced_vs_bare,"
             f"spans_per_tick={spans_per_tick:.1f}")
    table(rows, "observability overhead: traced vs bare serving loop")
    print(f"tracing on/off: {ratio:.3f}x frames/s "
          f"({spans_per_tick:.1f} spans/tick, "
          f"{len(obs.metrics.table())} metric keys, "
          f"{obs.tracer.dropped} dropped)")

    out = trace_out_path("obs_overhead")
    if out:
        obs.write_trace(out, process_label="obs_overhead")
        print(f"wrote Chrome trace to {out} "
              f"({obs.tracer.n_recorded} spans)")

    # ---- CI smoke: observation must be (nearly) free, and lossless ----
    assert obs.tracer.dropped == 0, \
        f"ring dropped {obs.tracer.dropped} spans at capacity " \
        f"{obs.tracer.capacity}"
    assert ratio >= SMOKE_TOLERANCE, (
        f"traced fps {fps['on']:.0f} < {SMOKE_TOLERANCE} x bare fps "
        f"{fps['off']:.0f} at {N_STREAMS} streams, depth {DEPTH}")
    return rows


if __name__ == "__main__":
    run()
