"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import os

import numpy as np

from repro.core.stats import summarize


def table(rows: list[dict], title: str) -> None:
    if not rows:
        print(f"== {title} == (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"\n== {title} ==")
    print(" | ".join(f"{c:>14s}" for c in cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            if isinstance(v, float):
                cells.append(f"{v:14.4g}")
            else:
                cells.append(f"{str(v):>14s}")
        print(" | ".join(cells))


def latency_row(name: str, xs, extra: dict | None = None) -> dict:
    s = summarize(np.asarray(xs, float))
    row = {
        "name": name,
        "mean_ms": s.mean * 1e3,
        "range_ms": s.range * 1e3,
        "range_over_mean_pct": s.range_over_mean_pct,
        "cv": s.cv,
        "p50_ms": s.p50 * 1e3,
        "p99_ms": s.p99 * 1e3,
    }
    if extra:
        row.update(extra)
    return row


# machine-readable mirror of every csv_line() emitted since the last drain;
# benchmarks/run.py drains this per module into BENCH_results.json
RESULTS: list[dict] = []


def csv_line(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"CSV,{name},{us_per_call:.2f},{derived}")
    RESULTS.append({
        "name": name,
        "us_per_call": float(us_per_call),
        "derived": derived,
    })


def drain_results() -> list[dict]:
    """Return and clear the accumulated csv_line records."""
    out = list(RESULTS)
    RESULTS.clear()
    return out


def trace_out_path(name: str) -> str | None:
    """Chrome-trace artifact path for a benchmark module, or None.

    ``benchmarks.run --trace-out DIR`` exports ``BENCH_TRACE_OUT``;
    tracing-aware benchmarks then write ``DIR/<name>.trace.json``
    (Perfetto-loadable) next to their CSV records."""
    directory = os.environ.get("BENCH_TRACE_OUT")
    if not directory:
        return None
    os.makedirs(directory, exist_ok=True)
    return os.path.join(directory, f"{name}.trace.json")
