"""Certifier wall-time: the static gate must stay cheap enough for CI.

tvcert's whole-envelope sweep (three batched rungs × capacity-8
occupancy/churn schedule, five ladder rungs, four Pallas kernels, twelve
cost rows) is pure tracing — ``jax.make_jaxpr`` plus jaxpr walking, no
XLA compile, no inference FLOP — so the full static build should finish
in seconds.  The gate asserted here (and re-asserted by the tvcert CI
job, which runs the same ``--check``): one full static certification of
the shipped tree under 60 s on the 2-core CI container.
"""
from __future__ import annotations

import time

from repro.analysis.cert import build_static, check, default_envelope

from .common import csv_line, table

BUDGET_S = 60.0                 # acceptance ceiling on 2-core CPU


def run() -> list[dict]:
    env = default_envelope()

    t0 = time.perf_counter()
    cert = build_static(env)
    build_s = time.perf_counter() - t0

    # the gate also pays one comparison pass; measure it where it runs
    t0 = time.perf_counter()
    fatal, notes = check(cert, cert)
    check_s = time.perf_counter() - t0

    n_rungs = len(env.rungs)
    n_programs = len(cert["programs"])
    rows = [{
        "phase": "build_static",
        "seconds": round(build_s, 3),
        "programs": n_programs,
        "rungs": n_rungs,
        "budget_s": BUDGET_S,
        "ok": build_s < BUDGET_S,
    }, {
        "phase": "check",
        "seconds": round(check_s, 3),
        "programs": n_programs,
        "rungs": n_rungs,
        "budget_s": BUDGET_S,
        "ok": (build_s + check_s) < BUDGET_S,
    }]
    table(rows, "tvcert overhead (full envelope, pure tracing)")
    csv_line("cert_overhead/build_static", build_s * 1e6,
             f"programs={n_programs}")
    csv_line("cert_overhead/check", check_s * 1e6,
             f"fatal={len(fatal)},notes={len(notes)}")

    assert build_s + check_s < BUDGET_S, (
        f"full certification took {build_s + check_s:.1f}s — "
        f"over the {BUDGET_S:.0f}s CI budget")
    assert not fatal, f"self-check of a fresh build found: {fatal[:3]}"
    return rows


if __name__ == "__main__":
    run()
