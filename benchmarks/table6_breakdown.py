"""Paper Table VI + Fig. 10: correlation of end-to-end latency with each
stage (read / pre / inference / post) — classifies pipelines into
inference-dominated vs post-processing-dominated."""
from repro.core.variance import classify, decompose
from repro.perception import SceneConfig, run_lane, run_lane_static, run_one_stage, run_two_stage
from .common import csv_line, table

N = 30


def run() -> list[dict]:
    rows = []
    for name, fn in [("one_stage", run_one_stage), ("two_stage", run_two_stage),
                     ("lane", run_lane), ("lane_static", run_lane_static)]:
        rec = fn(SceneConfig("city", seed=8), n=N)
        row = {"model": name}
        for st in rec.stages():
            row[f"corr_{st}"] = rec.correlation_with_end_to_end(st)
        row["class"] = classify(rec, threshold=0.35)
        rows.append(row)
        csv_line(f"table6/{name}", 0.0, row["class"])
    table(rows, "Table VI analogue — stage correlations & dominance class")
    return rows


if __name__ == "__main__":
    run()
