"""Scenario-trace replay benchmark: end-to-end variation under regime
changes (the paper's §III/§VII claim that *changing conditions* drive
inference-time variation, exercised through the full batched stack).

Replays a slice of the episode catalog deterministically (virtual time,
seeded modeled costs) and prints each episode's per-segment variation
table: the regime change should be visible as p99 / CV / rung-histogram
movement between segments, not averaged away.
"""
from __future__ import annotations

from repro.scenarios import ScenarioReplayer, compile_trace, get_episode

from .common import csv_line, table, trace_out_path

EPISODES = (
    "urban_rush_hour",
    "rain_onset_clear",
    "contention_spike",
    "latency_attack_ramp",
    "tunnel_entry",
)
SEED = 7
CAPACITY = 4


def run() -> None:
    sched = None
    summary_rows = []
    trace_path = trace_out_path("scenarios")
    obs = None
    if trace_path:
        from repro.obs import Observatory
        obs = Observatory()
    for name in EPISODES:
        trace = compile_trace(get_episode(name), seed=SEED)
        replayer = ScenarioReplayer(trace, scheduler=sched, capacity=CAPACITY,
                                    obs=obs)
        sched = replayer.scheduler
        report = replayer.run()

        rows = []
        for seg in report.segments:
            rows.append({
                "segment": seg.label,
                "t_start_s": seg.t_start,
                "frames": seg.frames,
                "drops": seg.drops,
                "miss_rate": seg.miss_rate,
                "p50_ms": seg.p50_ms,
                "p99_ms": seg.p99_ms,
                "cv": seg.cv,
                "quality": seg.mean_quality if seg.mean_quality is not None else float("nan"),
                "rungs": ",".join(f"{r}:{n}" for r, n in sorted(seg.rung_hist.items())),
                "fusion_loss": seg.fusion["dropped"] + seg.fusion["stranded"],
            })
        table(rows, f"{name} (seed {SEED}, {report.n_ticks} ticks)")

        tot = report.totals()
        p99s = [s.p99_ms for s in report.segments if s.p99_ms is not None]
        worst_p99 = max(p99s) if p99s else float("nan")
        csv_line(f"scenario_{name}", worst_p99 * 1e3,
                 derived=f"miss_rate={tot['miss_rate']},frames={tot['frames']},"
                         f"fusion_loss={tot['fusion_dropped'] + tot['fusion_stranded']}")
        summary_rows.append({
            "episode": name,
            "frames": tot["frames"],
            "drops": tot["drops"],
            "miss_rate": tot["miss_rate"],
            "worst_seg_p99_ms": worst_p99,
            "fusion_loss": tot["fusion_dropped"] + tot["fusion_stranded"],
        })
    table(summary_rows, "episode summary (deterministic replay)")
    if obs is not None:
        obs.write_trace(trace_path, process_label="scenarios")
        print(f"wrote Chrome trace to {trace_path} "
              f"({obs.tracer.n_recorded} spans, {obs.tracer.dropped} dropped)")


if __name__ == "__main__":
    run()
