"""§Roofline: the per-(arch × shape) roofline table from the dry-run
artifacts (reads results_single*.jsonl produced by repro.launch.dryrun)."""
import json
import os

from .common import table

CANDIDATES = ("results_single_fixed.jsonl", "results_single.jsonl")


def run() -> list[dict]:
    path = next((p for p in CANDIDATES if os.path.exists(p)), None)
    if path is None:
        print("roofline: no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --mesh single --out results_single.jsonl`")
        return []
    seen = {}
    for line in open(path):
        r = json.loads(line)
        seen[(r["arch"], r["shape"])] = r     # last record wins
    rows = []
    for (arch, shape), r in sorted(seen.items()):
        if r["status"] != "ok":
            rows.append({"arch": arch, "shape": shape, "status": r["status"],
                         "dominant": r.get("reason", r.get("error", ""))[:40],
                         "compute_ms": "", "memory_ms": "", "collective_ms": "",
                         "useful": "", "hbm_fit": ""})
            continue
        ma = r.get("memory_analysis", {})
        occupancy = (ma.get("argument_size_in_bytes", 0)
                     + ma.get("temp_size_in_bytes", 0)
                     + ma.get("output_size_in_bytes", 0)
                     - ma.get("alias_size_in_bytes", 0)) / 16e9
        rows.append({
            "arch": arch, "shape": shape, "status": "ok",
            "dominant": r["dominant"],
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "useful": r["useful_fraction"],
            "hbm_fit": f"{occupancy:.0%}" if ma else "?",
        })
    table(rows, f"§Roofline baseline table ({path})")
    return rows


if __name__ == "__main__":
    run()
