"""Paper Table I: mean / range / range-over-mean for the pipeline zoo.

The paper's claim: perception (DNN) tasks dominate latency AND variance;
several models exceed 100% range/mean.  Our zoo: one-stage detector,
two-stage detector, dynamic lane, static lane (ours), plus simulated
localization/planning tasks (AMCL/A*/DWA analogues via the scheduler sim's
jittered stage models, matching the paper's table structure).
"""
import numpy as np

from repro.perception import SceneConfig, run_lane, run_lane_static, run_one_stage, run_two_stage
from repro.sched import SimConfig, StageSpec, TaskSpec, simulate
from .common import csv_line, latency_row, table

N = 30


def run() -> list[dict]:
    cfg = SceneConfig("city", seed=2)
    rows = []
    for name, fn in [
        ("one_stage(det)", run_one_stage),
        ("two_stage(det)", run_two_stage),
        ("lane(dynamic)", run_lane),
        ("lane(static)", run_lane_static),
    ]:
        rec = fn(cfg, n=N)
        xs = rec.end_to_end_series()
        rows.append(latency_row(name, xs))
        csv_line(f"table1/{name}", float(np.mean(xs)) * 1e6,
                 f"cv={rows[-1]['cv']:.3f}")
    # localization / planning analogues (simulated, CPU-only tasks)
    rng = np.random.default_rng(0)
    for name, mean, jitter in [
        ("amcl(sim)", 0.0013, 1.1),
        ("orb_slam2(sim)", 0.053, 0.45),
        ("a_star(sim)", 0.079, 0.55),
        ("dwa(sim)", 0.023, 0.8),
    ]:
        xs = mean * rng.lognormal(0, jitter, 300)
        rows.append(latency_row(name, xs))
        csv_line(f"table1/{name}", float(np.mean(xs)) * 1e6, f"cv={rows[-1]['cv']:.3f}")
    table(rows, "Table I analogue — pipeline zoo latency statistics")
    return rows


if __name__ == "__main__":
    run()
