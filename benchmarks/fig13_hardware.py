"""Paper Fig. 13 + Table IX: device comparison.  We model the paper's four
devices (plus TPU v5e host) as (cpu_speed, accel_speed, cores) profiles and
replay the two pipeline shapes through the scheduler sim.  Claim (Insight
5): a stronger HOST shrinks the variance of post-processing-dominated
pipelines; a stronger ACCELERATOR shrinks one-stage variance."""
import numpy as np

from repro.core.stats import coefficient_of_variation as cv
from repro.sched import SimConfig, StageSpec, TaskSpec, simulate
from .common import csv_line, table

# (cpu_speedup, accel_speedup, cores) relative to Jetson AGX
DEVICES = {
    "agx_xavier": (1.0, 1.0, 8),
    "xavier_nx": (0.8, 0.7, 6),
    "fog_node_cpu": (2.2, 0.25, 8),     # strong CPU, no GPU
    "gpu_workstation": (2.8, 6.0, 28),
    "tpu_v5e_host": (2.5, 8.0, 16),
}


def run() -> list[dict]:
    rng = np.random.default_rng(2)
    props = rng.integers(2, 22, 400)
    scale = lambda j: props[j] / 6.0
    rows = []
    for dev, (cpu_s, acc_s, cores) in DEVICES.items():
        for model, stages in [
            ("pinet(2-stage)", (
                StageSpec("pre", "cpu", 0.010 / cpu_s, 0.05),
                StageSpec("infer", "accel", 0.060 / acc_s, 0.03),
                StageSpec("post", "cpu", 0.050 / cpu_s, 0.10, scale_fn=scale),
            )),
            ("yolo(1-stage)", (
                StageSpec("pre", "cpu", 0.010 / cpu_s, 0.05),
                StageSpec("infer", "accel", 0.140 / acc_s, 0.06),
                StageSpec("post", "cpu", 0.015 / cpu_s, 0.05),
            )),
        ]:
            res = simulate(
                [TaskSpec("m", 0.25, stages, n_jobs=150)],
                SimConfig(cpu_cores=cores, seed=0),
            )
            xs = res.latencies["m"]
            rows.append({
                "device": dev, "model": model,
                "mean_ms": xs.mean() * 1e3,
                "range_ms": float(np.ptp(xs)) * 1e3,
                "cv": cv(xs),
            })
        csv_line(f"fig13/{dev}", rows[-1]["mean_ms"] * 1e3, f"cv={rows[-1]['cv']:.3f}")
    table(rows, "Fig. 13 analogue — hardware profiles")
    return rows


if __name__ == "__main__":
    run()
