"""Paper Fig. 4: latency CDFs under city/residential/road scenarios.

Claim: two-stage (and lane) pipelines vary across scenarios; one-stage does
not (static work).
"""
import numpy as np

from repro.core.stats import coefficient_of_variation
from repro.perception import SCENARIOS, SceneConfig, run_one_stage, run_two_stage
from .common import csv_line, table

N = 24


def run() -> list[dict]:
    rows = []
    spread = {}
    for model, fn in [("one_stage", run_one_stage), ("two_stage", run_two_stage)]:
        means = []
        for scen in SCENARIOS:
            rec = fn(SceneConfig(scen, seed=4), n=N)
            xs = rec.end_to_end_series()
            means.append(xs.mean())
            rows.append({
                "model": model, "scenario": scen,
                "mean_ms": xs.mean() * 1e3,
                "p95_ms": float(np.percentile(xs, 95)) * 1e3,
                "cv": coefficient_of_variation(xs),
                "mean_proposals": float(rec.meta_series("num_proposals").mean()),
            })
        spread[model] = (max(means) - min(means)) / np.mean(means)
        csv_line(f"fig4/{model}", float(np.mean(means)) * 1e6,
                 f"cross_scenario_spread={spread[model]:.3f}")
    table(rows, "Fig. 4 analogue — scenario sensitivity")
    print(f"cross-scenario mean spread: one_stage={spread['one_stage']:.1%} "
          f"two_stage={spread['two_stage']:.1%} (paper: two-stage ≫ one-stage)")
    return rows


if __name__ == "__main__":
    run()
