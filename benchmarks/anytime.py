"""Beyond-paper artifact: the anytime subsystem's quality-vs-deadline
frontier.

The A/B that makes the subsystem's value measurable: at each deadline
budget, compare the contract controller against the static pipelines it
is built from —

* at a **tight** budget the best static pipeline misses nearly every
  frame; the controller degrades fidelity and collapses the miss rate
  while keeping quality well above the floor rung;
* at a **loose** budget the controller holds the top rung, matching the
  best static quality (no needless degradation);
* under a mid-run **contention window** (residual budget shrinks) the
  controller degrades through it and recovers after, with few switches
  (hysteresis, no thrashing).

Also demonstrates the scheduling-simulator wiring: the calibrated
ladder's per-rung stage means become ``TaskSpec.rungs`` chains, so
policy × fidelity interactions run in the discrete-event simulator.
"""
from __future__ import annotations

import numpy as np

from repro.anytime import (
    ContractController,
    FixedController,
    build_rungs,
    calibrate,
    default_rungs,
    run_anytime,
    rung_stage_specs,
)
from repro.perception import SceneConfig
from repro.sched import SimConfig, TaskSpec, simulate

from .common import csv_line, table

N_CAL = 10
N_FRAMES = 40


def _arm_row(label: str, budget_s: float, rep) -> dict:
    return {
        "arm": label,
        "budget_ms": budget_s * 1e3,
        "miss_pct": rep.miss_rate * 100,
        "quality": rep.mean_quality,
        "mean_ms": rep.mean_latency * 1e3,
        "p99_ms": rep.p99_latency * 1e3,
        "switches": rep.switches,
    }


def run() -> list[dict]:
    cfg = SceneConfig("city", seed=3)
    rungs = default_rungs()
    built = build_rungs(rungs, cfg)              # one compilation, shared
    ladder = calibrate(rungs, cfg, n=N_CAL, built=built)
    table(ladder.table(), "calibrated fidelity ladder (quality vs Scene.boxes)")
    for r in ladder:
        csv_line(f"anytime/rung/{r.name}", r.e2e_mean * 1e6, f"quality={r.quality:.3f}")

    top = ladder.top
    budgets = {
        "tight": 0.5 * top.e2e_mean,
        "mid": 1.0 * top.e2e_mean,
        "loose": 2.5 * top.e2e_mean,
    }

    rows = []
    ab: dict[str, dict] = {}
    for label, budget in budgets.items():
        static_top = run_anytime(
            ladder, cfg, budget, controller=FixedController(ladder),
            n=N_FRAMES, built=built,
        )
        static_floor = run_anytime(
            ladder, cfg, budget, controller=FixedController(ladder, ladder.floor.name),
            n=N_FRAMES, built=built,
        )
        anytime = run_anytime(
            ladder, cfg, budget, controller=ContractController(ladder),
            n=N_FRAMES, built=built,
        )
        rows.append(_arm_row(f"static[{top.name}]", budget, static_top))
        rows.append(_arm_row(f"static[{ladder.floor.name}]", budget, static_floor))
        rows.append(_arm_row("anytime", budget, anytime))
        ab[label] = {"static": static_top, "anytime": anytime}
        csv_line(
            f"anytime/frontier/{label}", anytime.mean_latency * 1e6,
            f"miss {static_top.miss_rate:.2f}->{anytime.miss_rate:.2f} "
            f"quality {static_top.mean_quality:.3f}->{anytime.mean_quality:.3f}",
        )
    table(rows, "quality vs p99 / deadline-miss frontier (static rungs vs anytime)")

    tight = ab["tight"]
    print(
        f"A/B @ tight budget ({budgets['tight']*1e3:.1f}ms): "
        f"miss {tight['static'].miss_rate*100:.0f}% -> "
        f"{tight['anytime'].miss_rate*100:.0f}%, "
        f"quality {tight['anytime'].mean_quality:.3f} "
        f"(floor rung alone: {ladder.floor.quality:.3f})"
    )

    # ---- contention window: residual budget dips for the middle third ----
    budget = 2.5 * top.e2e_mean
    lo, hi = N_FRAMES // 3, 2 * N_FRAMES // 3

    def budget_fn(i: int) -> float:
        return budget * 0.25 if lo <= i < hi else budget

    rep = run_anytime(
        ladder, cfg, budget, controller=ContractController(ladder),
        n=N_FRAMES, built=built, budget_fn=budget_fn,
    )
    t = rep.rung_trace()
    idx = [ladder.index(name) for name in t]
    print(
        f"contention window [{lo},{hi}): mean rung index "
        f"before={np.mean(idx[:lo]):.2f} during={np.mean(idx[lo:hi]):.2f} "
        f"after={np.mean(idx[hi:]):.2f}; switches={rep.switches} "
        f"miss_rate={rep.miss_rate:.3f}"
    )
    csv_line(
        "anytime/contention", rep.mean_latency * 1e6,
        f"switches={rep.switches} miss={rep.miss_rate:.3f}",
    )

    # ---- policy × fidelity in the scheduling simulator --------------------
    period = 1.2 * top.e2e_mean
    chains = tuple(rung_stage_specs(r) for r in ladder)
    sim_rows = []
    for label, rung_fn in [
        ("static[top]", lambda j: 0),
        ("degraded[mid]", lambda j: min(2, len(chains) - 1)),
        ("alternating", lambda j: 0 if j % 2 == 0 else len(chains) - 1),
    ]:
        t_spec = TaskSpec(
            "perception", period, chains[0], policy="DEADLINE",
            deadline_budget=0.8 * period, n_jobs=120,
            rungs=chains, rung_fn=rung_fn,
        )
        res = simulate([t_spec], SimConfig(cpu_cores=2, seed=5))
        xs = res.latencies["perception"]
        sim_rows.append({
            "schedule": label,
            "policy": "DEADLINE",
            "mean_ms": float(xs.mean()) * 1e3,
            "p99_ms": float(np.percentile(xs, 99)) * 1e3,
            "miss_pct": res.miss_rates["perception"] * 100,
            "throttles": res.throttle_events["perception"],
        })
    table(sim_rows, "policy × fidelity (simulator, per-rung stage chains)")
    return rows


if __name__ == "__main__":
    run()
