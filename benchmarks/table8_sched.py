"""Paper Fig. 12 + Table VIII: latency mean/percentiles/c_v under
SCHED_OTHER / FIFO / RR / DEADLINE (worst & mean budgets), single vs
compete — Insight 4 with the CBS-throttling mechanism."""
import numpy as np

from repro.core.stats import coefficient_of_variation as cv
from repro.sched import SimConfig, StageSpec, TaskSpec, simulate
from .common import csv_line, table

N_JOBS = 120


def _pinet(policy, budget=0.0, scale=None):
    prio = 99 if policy in ("FIFO", "RR") else 0
    return TaskSpec("pinet", 0.25, (
        StageSpec("pre", "cpu", 0.010, 0.05),
        StageSpec("infer", "accel", 0.060, 0.03),
        StageSpec("post", "cpu", 0.050, 0.10, scale_fn=scale),
    ), policy=policy, priority=prio, deadline_budget=budget, n_jobs=N_JOBS)


def _yolo():
    return TaskSpec("yolo", 0.25, (
        StageSpec("pre", "cpu", 0.010, 0.05),
        StageSpec("infer", "accel", 0.140, 0.03),
        StageSpec("post", "cpu", 0.015, 0.05),
    ), policy="OTHER", n_jobs=N_JOBS)


def run() -> list[dict]:
    rng = np.random.default_rng(1)
    props = rng.integers(2, 22, 400)
    scale = lambda j: props[j] / 6.0
    rows = []
    for label, policy, budget in [
        ("OTHER", "OTHER", 0.0), ("FIFO", "FIFO", 0.0), ("RR", "RR", 0.0),
        ("DEADLINE-1(worst)", "DEADLINE", 0.30),
        ("DEADLINE-2(mean)", "DEADLINE", 0.15),
    ]:
        for compete in (False, True):
            tasks = [_pinet(policy, budget, scale)]
            if compete:
                tasks.append(_yolo())
            res = simulate(tasks, SimConfig(cpu_cores=1, seed=0))
            xs = res.latencies["pinet"]
            rows.append({
                "policy": label, "compete": compete,
                "mean_ms": xs.mean() * 1e3,
                "p50_ms": float(np.percentile(xs, 50)) * 1e3,
                "p80_ms": float(np.percentile(xs, 80)) * 1e3,
                "p99_ms": float(np.percentile(xs, 99)) * 1e3,
                "cv": cv(xs),
                "throttles": res.throttle_events["pinet"],
            })
        csv_line(f"table8/{label}", rows[-1]["mean_ms"] * 1e3,
                 f"cv={rows[-1]['cv']:.3f}")
    table(rows, "Table VIII analogue — scheduling policies (PINet-like task)")
    return rows


if __name__ == "__main__":
    run()
